"""Attention: GQA projections, chunked online-softmax attention (the pure-XLA
scalable path; the Pallas kernel in ``repro.kernels`` is the TPU hot path),
sliding windows, logit softcaps, qk-norm, and a sequence-sharded
flash-decode for serving against huge KV caches.

The flash-decode (``decode_attention``) is the paper's §4.2 idea transposed:
*computation moves to where the state lives*. The KV cache is sharded over
the "model" axis on its sequence dim; each shard computes a partial
softmax-attention over its slice and the partials are stitched with an
LSE-combine (pmax/psum) — Part → Gather-at-shard → Stitch, exactly.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import modules as m
from repro.models import quant
from repro.models.layers import apply_rope, rms_norm_fp32, softcap

NEG_INF = -1.0e30


def init_attention(cfg: ModelConfig, key):
    ks = m.split_keys(key, 4)
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pairs = [
        m.named("wq", m.dense_init(ks[0], (d, H, hd),
                                   ("embed", "heads", "head_dim"))),
        m.named("wk", m.dense_init(ks[1], (d, K, hd),
                                   ("embed", "kv_heads", "head_dim"))),
        m.named("wv", m.dense_init(ks[2], (d, K, hd),
                                   ("embed", "kv_heads", "head_dim"))),
        m.named("wo", m.dense_init(ks[3], (H, hd, d),
                                   ("heads", "head_dim", "embed"),
                                   scale=1.0 / math.sqrt(H * hd))),
    ]
    if cfg.qk_norm:
        pairs.append(m.named("q_norm", m.ones_init((hd,), ("head_dim",))))
        pairs.append(m.named("k_norm", m.ones_init((hd,), ("head_dim",))))
    return m.merge(*pairs)


def project_q(params, x, cfg: ModelConfig, cos_sin=None):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm_fp32(q, params["q_norm"])
    if cos_sin is not None:
        q = apply_rope(q, *cos_sin)
    return q


def project_kv(params, x, cfg: ModelConfig, cos_sin=None):
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        k = rms_norm_fp32(k, params["k_norm"])
    if cos_sin is not None:
        k = apply_rope(k, *cos_sin)
    return k, v


def out_proj(params, y, x_dtype):
    return jnp.einsum("bshk,hkd->bsd", y, params["wo"].astype(x_dtype))


def _attn_scale(cfg: ModelConfig) -> float:
    return cfg.attn_scale if cfg.attn_scale is not None else cfg.head_dim ** -0.5


# ---------------------------------------------------------------------------
# Dense attention (bf16 probabilities; the cheap path for short sequences —
# under layer-remat its backward saves one (B,H,Sq,Skv) bf16 block).
# ---------------------------------------------------------------------------


def dense_attention(q, k, v, *, causal=True, window=None, cap=None,
                    scale=None, q_offset=0):
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    scale = hd ** -0.5 if scale is None else scale
    # g-major grouping (head h uses kv head h % K): reshaping H -> (G, K)
    # keeps a "model"-sharded H dim expressible as a sharded G dim, so the
    # big logit tensors stay sharded under GSPMD (k-major would replicate).
    qg = q.reshape(B, Sq, G, K, hd)
    logits = jnp.einsum("bqgkh,bskh->bgkqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cap)
    if causal:
        d = (q_offset + jnp.arange(Sq))[:, None] - jnp.arange(Skv)[None, :]
        ok = d >= 0
        if window is not None:
            ok &= d < window
        logits = jnp.where(ok[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bgkqs,bskh->bqgkh", p, v)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (pure XLA; O(chunk) memory).
# ---------------------------------------------------------------------------


def block_causal_attention(q, k, v, *, window=None, cap=None, scale=None,
                           chunk_kv=1024, block_q=2048, q_offset=0):
    """Causal attention with *static* triangular block skipping.

    The q range is cut into static blocks; block i only attends to the
    kv prefix it can see (and, with a sliding window, only from the first
    in-window block). Halves causal-attention flops vs the rectangular
    chunked scan — visible in the compiled HLO, hence in §Roofline.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    assert q_offset == 0 and Sq == Skv, "self-attention prefill only"
    nb = -(-Sq // block_q)
    outs = []
    for qi in range(nb):
        lo, hi = qi * block_q, min((qi + 1) * block_q, Sq)
        start = 0
        if window is not None:
            start = max(0, (lo - window) // chunk_kv * chunk_kv)
        outs.append(chunked_attention(
            q[:, lo:hi], k[:, start:hi], v[:, start:hi], causal=True,
            window=window, cap=cap, scale=scale, chunk_kv=chunk_kv,
            q_offset=lo - start))
    return jnp.concatenate(outs, axis=1)


def chunked_attention(q, k, v, *, causal=True, window=None, cap=None,
                      scale=None, chunk_kv=1024, q_offset=0):
    """q: (B,Sq,H,hd); k,v: (B,Skv,K,hd) with H % K == 0 (GQA).

    Scans over KV chunks with a streaming (max, sum, acc) softmax state, so
    peak logit memory is O(Sq * chunk_kv) instead of O(Sq * Skv). ``q_offset``
    is the absolute position of q[0] (for prefill continuation / decode).
    """
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    scale = hd ** -0.5 if scale is None else scale
    chunk_kv = min(chunk_kv, Skv)
    n_chunks = -(-Skv // chunk_kv)
    pad = n_chunks * chunk_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = q.reshape(B, Sq, G, K, hd)     # g-major; see dense_attention
    q_pos = q_offset + jnp.arange(Sq)

    kc = k.reshape(B, n_chunks, chunk_kv, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk_kv, K, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        mx, sm, acc = carry
        ci, k_i, v_i = inputs
        k_pos = ci * chunk_kv + jnp.arange(chunk_kv)
        logits = jnp.einsum("bqgkh,bckh->bqgkc", qg, k_i,
                            preferred_element_type=jnp.float32) * scale
        logits = softcap(logits, cap)
        valid = (k_pos < Skv)[None, None, None, None, :]
        if causal:
            d = q_pos[:, None] - k_pos[None, :]
            ok = d >= 0
            if window is not None:
                ok &= d < window
            valid = valid & ok[None, :, None, None, :]
        logits = jnp.where(valid, logits, NEG_INF)
        new_mx = jnp.maximum(mx, logits.max(axis=-1))
        p = jnp.exp(logits - new_mx[..., None])
        corr = jnp.exp(mx - new_mx)
        sm = sm * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqgkc,bckh->bqgkh", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (new_mx, sm, acc), None

    init = (jnp.full((B, Sq, G, K), NEG_INF, jnp.float32),
            jnp.zeros((B, Sq, G, K), jnp.float32),
            jnp.zeros((B, Sq, G, K, hd), jnp.float32))
    (mx, sm, acc), _ = jax.lax.scan(
        body, init, (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(sm, 1e-37)[..., None]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode against a sequence-sharded KV cache (flash-decode LSE combine).
# ---------------------------------------------------------------------------


def _decode_attn_local(q, k, v, pos, seq_offset, *, window, cap, scale):
    """Partial attention over a local cache slice.

    Returns (o, lse) fp32 where o is the *normalized* local attention
    output (softmax over the local slice only) and lse its log-sum-exp;
    the cross-shard stitch is o_glob = Σ o_i·exp(lse_i - m) / Σ exp(lse_i-m).
    """
    B, _, H, hd = q.shape
    _, S_l, K, _ = k.shape
    G = H // K
    qg = q.reshape(B, G, K, hd)         # g-major; see dense_attention
    logits = jnp.einsum("bgkh,bskh->bgks", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cap)
    k_pos = seq_offset + jnp.arange(S_l)
    ok = k_pos[None, :] <= pos[:, None]                       # (B, S_l)
    if window is not None:
        ok &= k_pos[None, :] > pos[:, None] - window
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    mx = logits.max(axis=-1)
    p = jnp.exp(logits - mx[..., None])
    sm = jnp.maximum(p.sum(axis=-1), 1e-37)
    # normalize in fp32 then cast, like dense_attention — keeps the static
    # Server's decode bit-identical to the paged engine's (which matters
    # for the serving equivalence tests, where one path recomputes tokens
    # the other produced incrementally)
    o = jnp.einsum("bgks,bskh->bgkh", (p / sm[..., None]).astype(v.dtype),
                   v, preferred_element_type=jnp.float32)
    lse = mx + jnp.log(sm)
    return o, lse


def decode_attention(q, k_cache, v_cache, pos, *, window=None, cap=None,
                     scale=None, dp_axes=("data",), seq_axis="model"):
    """q: (B,1,H,hd); caches: (B,S,K,hd) sharded (batch->dp, seq->model).

    pos: (B,) int32 — index of the newest token (attends to [0, pos]).
    Runs as shard_map over the mesh; each model shard attends over its local
    sequence slice; partials are combined with a max/LSE psum stitch.
    """
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    mesh = jax.sharding.get_abstract_mesh()
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    dp_b = dp if (dp and B % math.prod(mesh.shape[a] for a in dp) == 0) else ()
    dps = dp_b if dp_b else None
    # drop seq sharding when the cache length doesn't divide the axis
    # (e.g. whisper's 1500-frame cross cache on a 16-wide axis); the
    # LSE-stitch stays correct because num and den scale identically.
    if seq_axis not in mesh.axis_names or S % mesh.shape[seq_axis] != 0:
        seq_axis_eff = None
    else:
        seq_axis_eff = seq_axis

    def body(q, k, v, pos):
        if seq_axis_eff is not None:
            idx = jax.lax.axis_index(seq_axis_eff)
        else:
            idx = 0
        S_l = k.shape[1]
        o, lse = _decode_attn_local(q, k, v, pos, idx * S_l,
                                    window=window, cap=cap, scale=scale)
        mx = jax.lax.pmax(lse, seq_axis)
        w = jnp.exp(lse - mx)
        den = jax.lax.psum(w, seq_axis)
        num = jax.lax.psum(o * w[..., None], seq_axis)
        r = num / jnp.maximum(den, 1e-37)[..., None]       # (B_l, G, K, hd)
        return r.reshape(r.shape[0], 1, H, hd)

    out = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dps, None, None, None),
                  P(dps, seq_axis_eff, None, None),
                  P(dps, seq_axis_eff, None, None), P(dps)),
        out_specs=P(dps, None, None, None),
    )(q, k_cache, v_cache, pos)
    return out.astype(q.dtype)


def decode_attention_local(q, k_cache, v_cache, pos, *, window=None, cap=None,
                           scale=None):
    """Unsharded decode attention (smoke tests / cross-attention)."""
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    o, _ = _decode_attn_local(q, k_cache, v_cache, pos, 0,
                              window=window, cap=cap, scale=scale)
    B, G, K, hd = o.shape       # o is already normalized
    return o.reshape(B, 1, G * K, hd).astype(q.dtype)


def update_cache(cache, new, pos):
    """cache: (B,S,K,hd); new: (B,1,K,hd); pos: (B,) — scatter at positions."""
    B = cache.shape[0]
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0, 0)))(
            cache, new, pos)


def _constrain_pool(pages):
    """Keep a page pool sharded by kv head across the scatter update —
    without the constraint GSPMD is free to replicate the (large) pools
    between the KV write and the shard_map'd attention read."""
    tp, mesh = _paged_tp(pages.shape[2])
    if tp == 1:
        return pages
    return jax.lax.with_sharding_constraint(
        pages, jax.sharding.NamedSharding(
            mesh, P(None, None, "model", None)))


def update_paged_cache(pages, new, block_tables, pos):
    """Scatter one new KV row per sequence into its block-table page.

    pages: (num_blocks, block_size, K, hd); new: (B, 1, K, hd); pos: (B,)
    absolute write position. Inactive serving slots carry an all-zero table
    row, so their writes land in the reserved trash block 0 (never allocated
    to a request) and corrupt nothing.
    """
    bs = pages.shape[1]
    block_ids = jnp.take_along_axis(
        block_tables, (pos // bs)[:, None], axis=1)[:, 0]     # (B,)
    return _constrain_pool(
        pages.at[block_ids, pos % bs].set(new[:, 0].astype(pages.dtype)))


def update_paged_cache_chunk(pages, new, block_tables, q_start, q_lens):
    """Scatter a chunk of new KV rows per sequence into its pages.

    pages: (num_blocks, block_size, K, hd); new: (B, C, K, hd); q_start:
    (B,) absolute position of chunk row 0; q_lens: (B,) valid rows. Rows
    past q_lens are routed to the reserved trash block 0 (never allocated
    to a request), like an idle decode slot's write.
    """
    bs = pages.shape[1]
    B, C = new.shape[:2]
    nb = block_tables.shape[1]
    pos = q_start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]   # (B, C)
    idx = jnp.clip(pos // bs, 0, nb - 1)
    blk = jnp.take_along_axis(block_tables, idx, axis=1)            # (B, C)
    valid = jnp.arange(C)[None] < q_lens[:, None]
    blk = jnp.where(valid, blk, 0)                  # trash the padding rows
    return _constrain_pool(
        pages.at[blk.reshape(-1), (pos % bs).reshape(-1)].set(
            new.reshape(B * C, *new.shape[2:]).astype(pages.dtype)))


def update_paged_cache_ragged(pages, new, block_tables, ctx_lens, starts,
                              ends, row_seq):
    """Scatter a packed (ragged) multi-sequence chunk of KV into pages.

    pages: (num_blocks, block_size, K, hd); new: (1, T, K, hd) — chunks of
    up to S sequences packed back to back; sequence s owns flat rows
    [starts[s], ends[s]) and row_seq maps each flat row to its owner. Flat
    row t lands at absolute position ``ctx_lens[s] - (ends[s] - starts[s])
    + (t - starts[s])`` in sequence s's block table. Rows owned by nobody
    are routed to the reserved trash block 0, exactly like the padding
    rows of :func:`update_paged_cache_chunk` — same values, same
    destination rows, so the pool contents match the single-chunk path
    bit for bit.
    """
    bs = pages.shape[1]
    T = new.shape[1]
    nb = block_tables.shape[1]
    t = jnp.arange(T, dtype=jnp.int32)
    q_start = (ctx_lens - (ends - starts))[row_seq]           # (T,)
    valid = (t >= starts[row_seq]) & (t < ends[row_seq])
    pos = jnp.where(valid, q_start + (t - starts[row_seq]), 0)
    idx = jnp.clip(pos // bs, 0, nb - 1)
    blk = jnp.where(valid, block_tables[row_seq, idx], 0)
    return _constrain_pool(
        pages.at[blk, pos % bs].set(new[0].astype(pages.dtype)))


def replicate_over_model(x):
    """Gather ``x`` to replicated when the mesh has a nontrivial "model"
    axis (no-op otherwise). The serving TP invariant hangs on this: state
    shards by kv head (paged KV pools, per-slot cross K/V), per-head
    compute is exact on its shard, and the head-sharded result is
    gathered *before* any contraction that crosses heads (out-proj). The
    gather is an exact collective, so every weight contraction then runs
    whole on every shard in single-device op order — engine outputs stay
    bitwise identical on any mesh shape (docs/multi-host.md)."""
    mesh = jax.sharding.get_abstract_mesh()
    if "model" not in mesh.axis_names or mesh.shape["model"] <= 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*([None] * x.ndim))))


def _paged_tp(num_kv_heads: int):
    """(tp, mesh) for the serving kv-head-sharded paged-attention path.

    tp > 1 only when the ambient mesh has a "model" axis that divides the
    kv-head count — the pools shard by whole kv heads, so an indivisible
    count falls back to the replicated single-device path (the engine
    refuses such meshes up front; see spmd.sharding.paged_pool_pspec)."""
    mesh = jax.sharding.get_abstract_mesh()
    if "model" not in mesh.axis_names:
        return 1, None
    tp = mesh.shape["model"]
    if tp <= 1 or num_kv_heads % tp != 0:
        return 1, None
    return tp, mesh


def paged_decode_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                           window=None, cap=None, scale=None,
                           k_scale=None, v_scale=None):
    """Decode attention via block tables. q: (B,1,H,hd) -> (B,1,H,hd).

    On a mesh with a "model" axis that divides the kv-head count this runs
    under ``shard_map``: the page pools stay sharded by kv head, each
    shard runs the paged kernel over its own head slice (all G query heads
    of each local kv head — attention per head is complete on its shard,
    no cross-shard stitch), and only the host-replicated block table and
    context lengths are shared. Computation moves to where the KV lives —
    the paper's §4.2 argument, applied to the serving pools. Quantized
    pools pass their fp32 scale pools (same kv-head sharding, hd dim 1).
    """
    from repro.kernels import ops as kops
    B, _, H, hd = q.shape
    K = k_pages.shape[2]
    scale = hd ** -0.5 if scale is None else scale
    tp, mesh = _paged_tp(K)
    if tp == 1:
        o = kops.paged_attention(q[:, 0], k_pages, v_pages, block_tables,
                                 ctx_lens, window=window, cap=cap,
                                 scale=scale, k_scale=k_scale,
                                 v_scale=v_scale)
        return o[:, None].astype(q.dtype)
    G = H // K
    qg = q[:, 0].reshape(B, G, K, hd)         # g-major; see dense_attention

    def body(qg, kp, vp, bt, ctx, *scales):
        K_l = kp.shape[2]
        ks, vs = scales if scales else (None, None)
        o = kops.paged_attention(qg.reshape(B, G * K_l, hd), kp, vp, bt,
                                 ctx, window=window, cap=cap, scale=scale,
                                 k_scale=ks, v_scale=vs)
        return o.reshape(B, G, K_l, hd)

    extra = (k_scale, v_scale) if k_scale is not None else ()
    kv_spec = P(None, None, "model", None)    # rank-4, kv heads at axis 2
    o = jax.shard_map(
        body, mesh=mesh,
        in_specs=(kv_spec, kv_spec, kv_spec, P(None, None), P(None),
                  *([kv_spec] * len(extra))),
        out_specs=P(None, None, "model", None),
    )(qg, k_pages, v_pages, block_tables, ctx_lens, *extra)
    return replicate_over_model(o).reshape(B, 1, H, hd).astype(q.dtype)


def paged_chunk_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                          q_lens, *, window=None, cap=None, scale=None,
                          k_scale=None, v_scale=None):
    """Chunked-prefill attention via block tables: the C queries of one
    prompt chunk attend causally to the paged context (prior chunks' KV
    read through the table; this chunk's KV already scattered in).
    q: (B,C,H,hd) -> (B,C,H,hd). Sharded over kv heads exactly like
    :func:`paged_decode_attention` when the mesh allows."""
    from repro.kernels import ops as kops
    B, C, H, hd = q.shape
    K = k_pages.shape[2]
    scale = hd ** -0.5 if scale is None else scale
    tp, mesh = _paged_tp(K)
    if tp == 1:
        o = kops.paged_prefill_attention(q, k_pages, v_pages, block_tables,
                                         ctx_lens, q_lens, window=window,
                                         cap=cap, scale=scale,
                                         k_scale=k_scale, v_scale=v_scale)
        return o.astype(q.dtype)
    G = H // K
    qg = q.reshape(B, C, G, K, hd)            # g-major; see dense_attention

    def body(qg, kp, vp, bt, ctx, qlen, *scales):
        K_l = kp.shape[2]                     # (nb, bs, K_l, hd)
        ks, vs = scales if scales else (None, None)
        o = kops.paged_prefill_attention(
            qg.reshape(B, C, G * K_l, hd), kp, vp, bt, ctx, qlen,
            window=window, cap=cap, scale=scale, k_scale=ks, v_scale=vs)
        return o.reshape(B, C, G, K_l, hd)

    extra = (k_scale, v_scale) if k_scale is not None else ()
    kv_spec = P(None, None, "model", None)
    o = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, None, "model", None),
                  kv_spec, kv_spec, P(None, None), P(None),
                  P(None), *([kv_spec] * len(extra))),
        out_specs=P(None, None, None, "model", None),
    )(qg, k_pages, v_pages, block_tables, ctx_lens, q_lens, *extra)
    return replicate_over_model(o).reshape(B, C, H, hd).astype(q.dtype)


def stitch_paged_partials(os, lses):
    """Combine per-shard partial paged attentions into the global result.

    os: (S, ..., hd) locally-normalized fp32 outputs; lses: (...,) matching
    fp32 log-sum-exps (one entry per shard along axis 0). The combine is
    the flash-decode stitch ``decode_attention`` uses across its "model"
    shards: renormalize each partial by its share of the global softmax
    mass. Rows no shard attended (all lse <= -1e30) come out zero.
    """
    m = lses.max(axis=0)
    w = jnp.exp(lses - m[None])
    den = jnp.maximum(w.sum(axis=0), 1e-37)
    return (os * w[..., None]).sum(axis=0) / den[..., None]


def paged_shard_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                          n_shards, *, window=None, cap=None, scale=None):
    """Pool-sharded paged decode attention: blocks-axis sharding + stitch.

    The substrate for scaling the page pools past the kv-head count
    (multi-host serving, docs/multi-host.md): shard s holds the pages of
    table entries ``j % n_shards == s`` (round-robin stand-in for
    by-residence ownership), runs the partial-softmax kernel over its
    shard-local table, and the partials are LSE-stitched. Equivalent to
    :func:`paged_decode_attention`'s math for any n_shards — pinned
    against ``kernels.ref.paged_shard_attention_ref`` and the dense
    reference by the stitch tests. q: (B, H, hd) -> (B, H, hd).
    """
    from repro.kernels import ops as kops
    if n_shards < 1:
        raise ValueError(f"n_shards={n_shards} must be >= 1")
    B, nb = block_tables.shape
    entry = jnp.arange(nb)[None, :]
    parts = [kops.paged_attention_partial(
        q, k_pages, v_pages, block_tables, ctx_lens,
        jnp.broadcast_to(entry % n_shards == s, (B, nb)),
        window=window, cap=cap, scale=scale) for s in range(n_shards)]
    o = stitch_paged_partials(jnp.stack([p[0] for p in parts]),
                              jnp.stack([p[1] for p in parts]))
    return o.astype(q.dtype)


def paged_chunk_attention_xla(q, k_pages, v_pages, block_tables, ctx_lens,
                              q_lens, *, window=None, cap=None, scale=None,
                              k_scale=None, v_scale=None):
    """Pure-XLA chunked-prefill path: densify the block-table gather, then
    ``dense_attention``'s exact op sequence (fp32 logits, *normalized*
    softmax cast to bf16, then p @ v) with per-sequence query offsets.

    Mirroring ``dense_attention`` bit-for-bit matters: the engine promises
    greedy outputs identical to a monolithic prefill, and the masked-out
    padded keys contribute exact fp32 zeros, so only the probability
    rounding order could diverge — this keeps it the same. Padding rows
    (i >= q_lens) emit garbage; their KV went to the trash block and the
    engine discards their logits. Quantized pools dequantize right after
    the gather (same ``quant.dequantize_kv`` round-trip the kernels use,
    so attention operands are bit-identical across paths).
    """
    B, C, H, hd = q.shape
    _, bs, K, _ = k_pages.shape
    G = H // K
    scale = hd ** -0.5 if scale is None else scale
    k = k_pages[block_tables].reshape(B, -1, K, hd)
    v = v_pages[block_tables].reshape(B, -1, K, hd)
    if k_scale is not None:
        k = quant.dequantize_kv(k, k_scale[block_tables].reshape(B, -1, K, 1))
        v = quant.dequantize_kv(v, v_scale[block_tables].reshape(B, -1, K, 1))
    S = k.shape[1]
    qg = q.reshape(B, C, G, K, hd)
    logits = jnp.einsum("bqgkh,bskh->bgkqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cap)
    q_pos = (ctx_lens - q_lens)[:, None] + jnp.arange(C)[None]      # (B, C)
    d = q_pos[..., None] - jnp.arange(S)[None, None]                # (B,C,S)
    ok = d >= 0
    if window is not None:
        ok &= d < window
    logits = jnp.where(ok[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bgkqs,bskh->bqgkh", p, v)
    return o.reshape(B, C, H, hd).astype(q.dtype)


def ragged_chunk_attention_xla(q, k_pages, v_pages, block_tables, ctx_lens,
                               starts, ends, row_seq, *, window=None,
                               cap=None, scale=None, k_scale=None,
                               v_scale=None):
    """Pure-XLA packed (ragged) chunked-prefill path.

    q: (T, H, hd) flat packed rows (layout contract on
    ``kernels.ref.ragged_paged_prefill_attention_ref``). Gathers each
    packed sequence's rows into the dense (S, T, H, hd) layout, runs
    ``paged_chunk_attention_xla`` — the *same function, same op order* the
    single-chunk engine path uses, just with S batch rows instead of 1 —
    and scatters the rows back flat. The gather/scatter are exact copies,
    so per-row outputs match the single-chunk path bit for bit; rows owned
    by no sequence come back zero.
    """
    T = q.shape[0]
    t = jnp.arange(T, dtype=jnp.int32)
    q_lens = ends - starts
    gidx = jnp.clip(starts[:, None] + t[None], 0, T - 1)      # (S, T)
    od = paged_chunk_attention_xla(
        q[gidx], k_pages, v_pages, block_tables, ctx_lens, q_lens,
        window=window, cap=cap, scale=scale, k_scale=k_scale,
        v_scale=v_scale)                                      # (S, T, H, hd)
    off = jnp.clip(t - starts[row_seq], 0, T - 1)
    o = od[row_seq, off]                                      # (T, H, hd)
    valid = (t >= starts[row_seq]) & (t < ends[row_seq])
    return jnp.where(valid[:, None, None], o, 0.0).astype(q.dtype)


def ragged_chunk_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                           starts, ends, row_seq, *, window=None, cap=None,
                           scale=None, k_scale=None, v_scale=None):
    """Packed (ragged) chunked-prefill attention via block tables: chunks
    of up to S sequences ride one flat (1, T, H, hd) token batch, each row
    attending causally to its owner's paged context (the chunk's KV
    already scattered in). Sharded over kv heads exactly like
    :func:`paged_chunk_attention` when the mesh allows."""
    from repro.kernels import ops as kops
    _, T, H, hd = q.shape
    K = k_pages.shape[2]
    scale = hd ** -0.5 if scale is None else scale
    tp, mesh = _paged_tp(K)
    if tp == 1:
        o = kops.ragged_paged_prefill_attention(
            q[0], k_pages, v_pages, block_tables, ctx_lens, starts, ends,
            row_seq, window=window, cap=cap, scale=scale,
            k_scale=k_scale, v_scale=v_scale)
        return o[None].astype(q.dtype)
    G = H // K
    qg = q[0].reshape(T, G, K, hd)            # g-major; see dense_attention

    def body(qg, kp, vp, bt, ctx, st, en, rs, *scales):
        K_l = kp.shape[2]
        ks, vs = scales if scales else (None, None)
        o = kops.ragged_paged_prefill_attention(
            qg.reshape(T, G * K_l, hd), kp, vp, bt, ctx, st, en, rs,
            window=window, cap=cap, scale=scale, k_scale=ks, v_scale=vs)
        return o.reshape(T, G, K_l, hd)

    extra = (k_scale, v_scale) if k_scale is not None else ()
    kv_spec = P(None, None, "model", None)
    o = jax.shard_map(
        body, mesh=mesh,
        in_specs=(kv_spec, kv_spec, kv_spec, P(None, None), P(None),
                  P(None), P(None), P(None), *([kv_spec] * len(extra))),
        out_specs=P(None, None, "model", None),
    )(qg, k_pages, v_pages, block_tables, ctx_lens, starts, ends, row_seq,
      *extra)
    return replicate_over_model(o).reshape(1, T, H, hd).astype(q.dtype)


def ragged_chunk_update_attend(q, k_new, v_new, k_pages, v_pages,
                               block_tables, ctx_lens, starts, ends,
                               row_seq, *, window=None, cap=None,
                               scale=None, k_scale=None, v_scale=None):
    """Scatter a packed chunk's KV into the pages and attend, fused when
    the backend allows.

    q: (1, T, H, hd); k_new/v_new: (1, T, K, hd) — same flat row layout.
    Returns ``(o, k_pages, v_pages)``. On the single-shard Pallas path the
    scatter rides inside the ragged kernel (aliased page outputs); the XLA
    path and the kv-head-sharded mesh path run
    :func:`update_paged_cache_ragged` then the attend — same pool bytes,
    same outputs.

    Quantized pools (``k_scale``/``v_scale`` given): the chunk's bf16 KV
    is quantized here — chunk-sized, so no bf16 copy of the *pool* ever
    materializes — and its scale rows are scattered into the scale pools
    *before* the fused kernel launches (the kernel reads scale pages for
    the dequant). Returns ``(o, k_pages, v_pages, k_scale, v_scale)``.
    """
    from repro.kernels import ops as kops
    K = k_pages.shape[2]
    tp, _ = _paged_tp(K)
    if k_scale is not None:
        kvd = quant.kv_dtype_name(k_pages.dtype)
        kq, ksr = quant.quantize_kv(k_new, kvd)      # (1,T,K,hd),(1,T,K,1)
        vq, vsr = quant.quantize_kv(v_new, kvd)
        ks = update_paged_cache_ragged(k_scale, ksr, block_tables, ctx_lens,
                                       starts, ends, row_seq)
        vs = update_paged_cache_ragged(v_scale, vsr, block_tables, ctx_lens,
                                       starts, ends, row_seq)
        if tp == 1:
            o, kc, vc = kops.ragged_prefill_update_attend(
                q[0], kq[0], vq[0], k_pages, v_pages, block_tables,
                ctx_lens, starts, ends, row_seq, window=window, cap=cap,
                scale=scale, k_scale=ks, v_scale=vs)
            return o[None].astype(q.dtype), kc, vc, ks, vs
        kc = update_paged_cache_ragged(k_pages, kq, block_tables, ctx_lens,
                                       starts, ends, row_seq)
        vc = update_paged_cache_ragged(v_pages, vq, block_tables, ctx_lens,
                                       starts, ends, row_seq)
        o = ragged_chunk_attention(q, kc, vc, block_tables, ctx_lens,
                                   starts, ends, row_seq, window=window,
                                   cap=cap, scale=scale, k_scale=ks,
                                   v_scale=vs)
        return o, kc, vc, ks, vs
    if tp == 1:
        o, kc, vc = kops.ragged_prefill_update_attend(
            q[0], k_new[0], v_new[0], k_pages, v_pages, block_tables,
            ctx_lens, starts, ends, row_seq, window=window, cap=cap,
            scale=scale)
        return o[None].astype(q.dtype), kc, vc
    kc = update_paged_cache_ragged(k_pages, k_new, block_tables, ctx_lens,
                                   starts, ends, row_seq)
    vc = update_paged_cache_ragged(v_pages, v_new, block_tables, ctx_lens,
                                   starts, ends, row_seq)
    o = ragged_chunk_attention(q, kc, vc, block_tables, ctx_lens, starts,
                               ends, row_seq, window=window, cap=cap,
                               scale=scale)
    return o, kc, vc


def attention_scale(cfg: ModelConfig) -> float:
    return _attn_scale(cfg)


def sharded_attention(q, k, v, cfg: ModelConfig, **kw):
    """Full-sequence attention with an automatic sequence-parallel fallback.

    When num_heads doesn't divide the "model" axis (starcoder2's 24,
    whisper's 20, qwen2-vl's 12 on a 16-wide axis), head-sharding cannot
    apply and GSPMD would replicate the whole attention computation on every
    chip. Instead we constrain q (and the output) to be sharded over "model"
    on the *query sequence* dim — causal masking is position-based, so each
    shard computes its own q rows against full K/V: attention flops drop by
    the model-axis size.
    """
    from repro.kernels import ops as kops
    mesh = jax.sharding.get_abstract_mesh()
    tp = mesh.shape.get("model", 1)
    Sq = q.shape[1]
    if tp > 1 and cfg.num_heads % tp != 0 and Sq % tp == 0:
        from repro.spmd.sharding import batch_spec
        b = batch_spec(q.shape[0], mesh, extra_dims=0)
        spec = P(b[0] if len(b) else None, "model", None, None)
        sh = jax.sharding.NamedSharding(mesh, spec)
        q = jax.lax.with_sharding_constraint(q, sh)
        y = kops.flash_attention(q, k, v, **kw)
        return jax.lax.with_sharding_constraint(y, sh)
    return kops.flash_attention(q, k, v, **kw)
