"""Param-tree conventions for the SPMD model zoo.

Every ``init_*`` function returns ``(params, specs)`` where ``params`` is a
nested dict of arrays and ``specs`` mirrors it with tuples of *logical axis
names* (resolved to mesh axes by ``repro.spmd.sharding``). This mirrors the
paper's separation of graph definition from placement: the logical spec is a
placement *constraint*, the sharding rules are the placement *decision*.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def dense_init(key, shape, axes, scale: float | None = None, dtype=jnp.float32):
    """He/Glorot-ish truncated-normal init; returns (param, logical axes)."""
    if scale is None:
        fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
        scale = 1.0 / math.sqrt(fan_in)
    p = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
    assert len(axes) == len(shape), (shape, axes)
    return p, tuple(axes)


def zeros_init(shape, axes, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), tuple(axes)


def ones_init(shape, axes, dtype=jnp.float32):
    return jnp.ones(shape, dtype), tuple(axes)


def merge(*pairs: tuple[dict, dict]) -> tuple[dict, dict]:
    params, specs = {}, {}
    for p, s in pairs:
        params.update(p)
        specs.update(s)
    return params, specs


def named(name: str, pair: tuple[PyTree, PyTree]) -> tuple[dict, dict]:
    return {name: pair[0]}, {name: pair[1]}


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def stack_layer_params(pairs: list[tuple[PyTree, PyTree]]) -> tuple[PyTree, PyTree]:
    """Stack per-layer param trees along a new leading "layers" axis (for
    lax.scan over layers). Specs gain a leading "layers" logical axis."""
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[p for p, _ in pairs])
    specs = jax.tree.map(
        lambda s: ("layers",) + tuple(s),
        pairs[0][1],
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
    return params, specs


def cast_tree(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def count_params(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))
