"""The paper's §4.4 in one script: train the same model under async, sync
and sync+backup-worker coordination with injected stragglers, and print the
step-time/discard comparison (Figures 4 & 8).

Run: PYTHONPATH=src python examples/ps_training.py
"""

import numpy as np

from repro.core.cluster import Cluster
from repro.core.graph import Graph
from repro.ps.training import PSTrainer, linear_model

rng = np.random.default_rng(0)
W_TRUE = rng.normal(0, 1, (32, 16)).astype(np.float32)


def batch_fn(w, s):
    x = rng.normal(0, 1, (64, 32)).astype(np.float32)
    return x, (x @ W_TRUE).argmax(-1)


def main():
    n_workers, steps = 6, 12
    print(f"{'mode':<10}{'median step':>14}{'p90 step':>12}"
          f"{'final loss':>12}{'discarded':>11}")
    for mode, backup in (("async", 0), ("sync", 0), ("backup", 2)):
        g = Graph()
        cl = Cluster(ps=2, worker=n_workers)
        tr = PSTrainer(linear_model(g, 32, 16, 2), cl, mode=mode,
                       n_workers=n_workers, backup_workers=backup, lr=0.3,
                       straggler_s=0.03, straggler_every=3)
        stats = tr.train(steps, batch_fn)
        med = np.median(stats.step_times) * 1e3
        p90 = np.percentile(stats.step_times, 90) * 1e3
        print(f"{mode:<10}{med:>12.1f}ms{p90:>10.1f}ms"
              f"{np.mean(stats.losses[-4:]):>12.3f}"
              f"{stats.discarded:>11}")
    print("\nbackup workers cut the straggler tail (paper Fig. 8); async "
          "hides it entirely at the cost of stale gradients (Fig. 4a).")


if __name__ == "__main__":
    main()
