"""Quickstart: the two faces of the system in ~60 lines.

1. The paper-faithful dataflow engine: build a graph with mutable state on
   parameter-server tasks, differentiate it (user-level, §4.1) and train.
2. The TPU-native SPMD path: the same model family as a pjit-able function
   over a device mesh — train a smoke-size assigned architecture.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np


def dataflow_engine_demo():
    from repro.core.cluster import Cluster
    from repro.core.gradients import gradients
    from repro.core.graph import Graph
    from repro.core.session import Session
    import repro.core.ops, repro.core.variables  # noqa: E401,F401

    g = Graph()
    cluster = Cluster(ps=2, worker=1)             # 2 param servers, 1 worker
    w = g.apply("Variable", var_name="w", device="ps:*",
                initial=np.zeros((4, 2), np.float32))
    x = g.placeholder("x")
    y = g.placeholder("y")
    wr = g.apply("Read", w)
    logits = g.apply("MatMul", x, wr)
    loss = g.apply("SoftmaxXent", logits, y)
    (gw,) = gradients(loss, [wr])
    train = g.apply("AssignSub", w, g.apply("Mul", g.constant(0.5), gw))

    sess = Session(g, cluster, default_device="worker:0")
    rng = np.random.default_rng(0)
    W_true = rng.normal(size=(4, 2)).astype(np.float32)
    for step in range(50):
        xv = rng.normal(size=(64, 4)).astype(np.float32)
        yv = (xv @ W_true).argmax(-1)
        lv = sess.run([loss, train], {x: xv, y: yv})[0]
    print(f"[dataflow] 50 PS-training steps, final loss {float(lv):.3f}")


def spmd_demo():
    import jax
    from repro.config import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import train

    cfg = get_config("qwen3_moe_30b_a3b", smoke=True)   # reduced MoE config
    mesh = make_host_mesh(1, 1)
    _, _, losses = train(cfg, steps=30, batch=8, seq=32, mesh=mesh)
    print(f"[spmd] 30 steps of {cfg.name}: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    dataflow_engine_demo()
    spmd_demo()
