"""Continuous-batching serving example: smoke-size gemma2 (alternating
local/global attention + logit softcaps — both flow through the paged
decode kernel) served through the block-paged engine with staggered
arrivals and per-request horizons, then smoke-size mamba2 through the
same engine — the SSM runner swaps the paged KV cache for constant-size
per-slot state, and the serve loop does not change.

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.config import get_config
from repro.launch.mesh import make_host_mesh
from repro.serving import InferenceEngine, Request, SamplingParams


def serve_ssm():
    cfg = get_config("mamba2_370m", smoke=True)
    mesh = make_host_mesh(1, 1)
    eng = InferenceEngine(cfg, mesh, max_batch=4, block_size=16, max_len=96,
                          max_num_batched_tokens=4 + 16)
    rng = np.random.default_rng(1)
    reqs = [Request(rng.integers(0, cfg.vocab_size, 24).astype(np.int32),
                    max_new=6 + 2 * (i % 3)) for i in range(6)]
    outs = eng.run(reqs, arrival_steps=[0, 0, 2, 4, 6, 8])
    print(f"[serve_lm] mamba2 ({type(eng.runner).__name__}): "
          f"{eng.stats['tokens']} tokens in {eng.stats['steps']} steps, "
          f"first ids {outs[reqs[0].rid][:6].tolist()}")


def main():
    cfg = get_config("gemma2_27b", smoke=True)
    mesh = make_host_mesh(1, 1)
    eng = InferenceEngine(cfg, mesh, max_batch=4, block_size=16, max_len=96)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        sp = SamplingParams(temperature=0.0 if i % 2 == 0 else 0.8,
                            top_k=0 if i % 2 == 0 else 16, seed=i)
        reqs.append(Request(
            rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
            max_new=8 + 4 * (i % 3), sampling=sp))
    arrivals = [0, 0, 0, 2, 4, 6, 8, 10, 12, 14]
    outs = eng.run(reqs, arrival_steps=arrivals)
    for i, r in enumerate(reqs[:4]):
        kind = "greedy" if r.sampling.temperature == 0 else "sampled"
        print(f"[serve_lm] req {i} ({kind}, max_new={r.max_new}): "
              f"{outs[r.rid][:6].tolist()}")
    s = eng.stats
    print(f"[serve_lm] {s['tokens']} tokens, {s['steps']} steps, "
          f"{s['prefill_chunks']} prefill chunks, "
          f"{s['cache_hit_tokens']} cache-hit tokens, "
          f"peak_block_util={s['peak_block_utilization']:.2f}, "
          f"{s['tok_s']:.1f} tok/s incl. compile")
    serve_ssm()


if __name__ == "__main__":
    main()
