"""Batched serving example: smoke-size model, batched requests through
prefill + KV-cache decode (the paper's production-inference requirement,
§2.1). Run: PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np

from repro.config import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Request, Server


def main():
    cfg = get_config("gemma2_27b", smoke=True)   # local/global + softcaps
    server = Server(cfg, make_host_mesh(1, 1), max_batch=8,
                    prompt_len=32, max_len=96)
    rng = np.random.default_rng(0)
    batches = 3
    total_tok, t0 = 0, time.time()
    for b in range(batches):
        reqs = [Request(rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
                        max_new=24) for _ in range(8)]
        outs = server.serve_batch(reqs)
        total_tok += sum(len(o) for o in outs)
        print(f"[serve_lm] batch {b}: first output {outs[0][:6].tolist()}")
    dt = time.time() - t0
    print(f"[serve_lm] {total_tok} tokens in {dt:.2f}s "
          f"({total_tok/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
