"""Continuous-batching serving example: smoke-size gemma2 (alternating
local/global attention + logit softcaps — both flow through the paged
decode kernel) served through the block-paged engine with staggered
arrivals and per-request horizons, then smoke-size mamba2 through the
same engine — the SSM runner swaps the paged KV cache for constant-size
per-slot state, and the serve loop does not change — and finally
speculative draft-and-verify decoding (a self-draft accepts nearly every
proposal, so the accept-length stat shows the mechanism working; greedy
outputs are byte-identical either way — see docs/speculative.md).

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.config import get_config
from repro.launch.mesh import make_host_mesh
from repro.serving import InferenceEngine, Request, SamplingParams


def serve_ssm():
    cfg = get_config("mamba2_370m", smoke=True)
    mesh = make_host_mesh(1, 1)
    eng = InferenceEngine(cfg, mesh, max_batch=4, block_size=16, max_len=96,
                          max_num_batched_tokens=4 + 16)
    rng = np.random.default_rng(1)
    reqs = [Request(rng.integers(0, cfg.vocab_size, 24).astype(np.int32),
                    max_new=6 + 2 * (i % 3)) for i in range(6)]
    outs = eng.run(reqs, arrival_steps=[0, 0, 2, 4, 6, 8])
    print(f"[serve_lm] mamba2 ({type(eng.runner).__name__}): "
          f"{eng.stats['tokens']} tokens in {eng.stats['steps']} steps, "
          f"first ids {outs[reqs[0].rid][:6].tolist()}")


def serve_speculative():
    cfg = get_config("starcoder2_3b", smoke=True)
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(4)]
    plain = InferenceEngine(cfg, mesh, max_batch=4, block_size=16,
                            max_len=96)
    base = plain.run([Request(p, max_new=8) for p in prompts])
    # self-draft (shared params): every greedy proposal the draft makes
    # agrees with the target, so k=2 emits up to 3 tokens per slot-step
    spec = InferenceEngine(cfg, mesh, max_batch=4, block_size=16,
                           max_len=96, params=plain.params,
                           draft_params=plain.params,
                           num_speculative_tokens=2)
    reqs = [Request(p, max_new=8) for p in prompts]
    outs = spec.run(reqs)
    same = all(np.array_equal(outs[r.rid], b)
               for r, b in zip(reqs, base.values()))
    print(f"[serve_lm] speculative ({type(spec.runner).__name__}, k=2): "
          f"mean_accept_len={spec.stats['mean_accept_len']:.2f}, "
          f"{spec.stats['steps']} steps vs {plain.stats['steps']} plain, "
          f"byte-identical={same}")


def main():
    cfg = get_config("gemma2_27b", smoke=True)
    mesh = make_host_mesh(1, 1)
    eng = InferenceEngine(cfg, mesh, max_batch=4, block_size=16, max_len=96)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        sp = SamplingParams(temperature=0.0 if i % 2 == 0 else 0.8,
                            top_k=0 if i % 2 == 0 else 16, seed=i)
        reqs.append(Request(
            rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
            max_new=8 + 4 * (i % 3), sampling=sp))
    arrivals = [0, 0, 0, 2, 4, 6, 8, 10, 12, 14]
    outs = eng.run(reqs, arrival_steps=arrivals)
    for i, r in enumerate(reqs[:4]):
        kind = "greedy" if r.sampling.temperature == 0 else "sampled"
        print(f"[serve_lm] req {i} ({kind}, max_new={r.max_new}): "
              f"{outs[r.rid][:6].tolist()}")
    s = eng.stats
    print(f"[serve_lm] {s['tokens']} tokens, {s['steps']} steps, "
          f"{s['prefill_chunks']} prefill chunks, "
          f"{s['cache_hit_tokens']} cache-hit tokens, "
          f"peak_block_util={s['peak_block_utilization']:.2f}, "
          f"{s['tok_s']:.1f} tok/s incl. compile")
    serve_ssm()
    serve_speculative()


if __name__ == "__main__":
    main()
