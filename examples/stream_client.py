"""Stdlib SSE client for the serving front-end — also the CI smoke probe.

Waits for the server's ``/health`` to come up (the first request triggers
jit compilation, so allow minutes on CPU), streams one ``POST /generate``
request token by token, then scrapes ``/metrics`` and ``/health`` and
asserts the counters moved. Exits non-zero on any failed expectation, so
CI can run it directly against a backgrounded
``python -m repro.launch.serve --http``:

  PYTHONPATH=src python -m repro.launch.serve --arch glm4_9b --smoke \\
      --http 127.0.0.1:8311 &
  PYTHONPATH=src python examples/stream_client.py --port 8311

Pure stdlib (http.client + json): no requests/aiohttp dependency — the
wire format is plain HTTP/1.1 + Server-Sent Events.
"""

from __future__ import annotations

import argparse
import http.client
import json
import sys
import time


def wait_for_health(host: str, port: int, timeout: float) -> dict:
    deadline = time.monotonic() + timeout
    last_err = None
    while time.monotonic() < deadline:
        try:
            conn = http.client.HTTPConnection(host, port, timeout=10)
            conn.request("GET", "/health")
            resp = conn.getresponse()
            body = json.loads(resp.read().decode())
            conn.close()
            if resp.status == 200 and body.get("status") == "ok":
                return body
            last_err = f"status={resp.status} body={body}"
        except OSError as e:
            last_err = str(e)
        time.sleep(0.5)
    raise SystemExit(f"[stream_client] server never became healthy "
                     f"within {timeout}s: {last_err}")


def stream_generate(host: str, port: int, prompt: list[int], max_new: int,
                    timeout: float) -> list[int]:
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    body = json.dumps({"prompt": prompt, "max_new": max_new})
    conn.request("POST", "/generate", body=body,
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    if resp.status != 200:
        raise SystemExit(f"[stream_client] POST /generate -> {resp.status}: "
                         f"{resp.read().decode()!r}")
    tokens: list[int] = []
    done = None
    while True:
        line = resp.readline()          # SSE: incremental, line-delimited
        if not line:
            break
        line = line.decode().strip()
        if not line.startswith("data: "):
            continue
        data = line[len("data: "):]
        if data == "[DONE]":
            break
        event = json.loads(data)
        if event.get("done"):
            done = event
        else:
            tokens.append(event["token"])
            print(f"[stream_client] token[{event['index']}] = "
                  f"{event['token']}", flush=True)
    conn.close()
    if done is None or done.get("n_tokens") != len(tokens):
        raise SystemExit(f"[stream_client] stream ended badly: "
                         f"done={done} n_streamed={len(tokens)}")
    return tokens


def scrape(host: str, port: int, path: str) -> tuple[int, str]:
    conn = http.client.HTTPConnection(host, port, timeout=30)
    conn.request("GET", path)
    resp = conn.getresponse()
    body = resp.read().decode()
    conn.close()
    return resp.status, body


def metric_value(metrics: str, name: str) -> float:
    for line in metrics.splitlines():
        if line.startswith(name + " "):
            return float(line.split()[1])
    raise SystemExit(f"[stream_client] metric {name} missing from /metrics")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--timeout", type=float, default=600,
                    help="seconds to wait for health / first token "
                    "(first request jit-compiles the step)")
    args = ap.parse_args()

    health = wait_for_health(args.host, args.port, args.timeout)
    print(f"[stream_client] healthy: {health}")
    prompt = [1 + (i % 97) for i in range(args.prompt_len)]
    tokens = stream_generate(args.host, args.port, prompt, args.max_new,
                             args.timeout)
    assert len(tokens) == args.max_new, (len(tokens), args.max_new)

    status, metrics = scrape(args.host, args.port, "/metrics")
    assert status == 200, status
    for line in metrics.splitlines():
        if line.startswith(("repro_engine_tokens_total",
                            "repro_engine_requests_done_total",
                            "repro_engine_ttft_seconds_count",
                            "repro_frontend_requests_submitted_total")):
            print(f"[stream_client] {line}")
    assert metric_value(metrics, "repro_engine_tokens_total") \
        >= args.max_new
    assert metric_value(metrics, "repro_engine_requests_done_total") >= 1
    assert metric_value(metrics, "repro_engine_ttft_seconds_count") >= 1
    assert metric_value(metrics,
                        "repro_frontend_requests_submitted_total") >= 1

    status, body = scrape(args.host, args.port, "/health")
    assert status == 200 and json.loads(body)["status"] == "ok", body
    print(f"[stream_client] OK: streamed {len(tokens)} tokens "
          f"{tokens}, metrics and health verified")


if __name__ == "__main__":
    sys.exit(main())
