"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on CPU, with checkpointing + resume + best-metric retention.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses

from repro.config import ModelConfig, OptimizerConfig, ParallelConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.train import train

# ~100M params: 12L, d=512, vocab 32k -> 2*32768*512 + 12*(4*512^2*?) ...
CFG_100M = ModelConfig(
    name="demo-100m",
    family="dense",
    num_layers=10,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32768,
    rope_theta=10000.0,
    mlp_activation="silu",
    norm="rmsnorm",
    tie_embeddings=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    n_params = CFG_100M.param_count()
    print(f"[train_lm] {CFG_100M.name}: {n_params/1e6:.1f}M params")
    mesh = make_host_mesh(1, 1)
    pcfg = ParallelConfig(remat="full", microbatches=2)
    ocfg = OptimizerConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    _, _, losses = train(CFG_100M, steps=args.steps, batch=args.batch,
                         seq=args.seq, mesh=mesh, pcfg=pcfg, ocfg=ocfg,
                         ckpt_dir=args.ckpt, ckpt_every=50,
                         resume=args.resume)
    print(f"[train_lm] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {len(losses)} steps")
    assert losses[-1] < losses[0], "model failed to learn"


if __name__ == "__main__":
    main()
