#!/usr/bin/env python
"""Dead-link checker for the repo's markdown tree.

Walks every ``*.md`` under the repo (docs/, READMEs, ROADMAP, ...),
extracts inline ``[text](target)`` links, and fails when a *relative*
target does not resolve to an existing file or directory. External
(http/https/mailto) and pure-anchor links are skipped; a ``#fragment``
suffix on a relative link is stripped before resolution (anchors are not
validated — only file existence is).

Run from anywhere:  python tools/check_links.py [repo_root]
Exit status 1 on any dead link — CI runs this as the docs gate, and
``tests/test_docs_links.py`` runs it under tier-1.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
SKIP_DIRS = {".git", "__pycache__", ".claude", "node_modules",
             ".venv", ".pytest_cache", ".hypothesis"}


def markdown_files(root: Path):
    for p in sorted(root.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.parts):
            yield p


def dead_links(root: Path) -> list[tuple[Path, str]]:
    """(markdown file, link target) pairs whose relative target is dead."""
    bad = []
    for md in markdown_files(root):
        for m in LINK_RE.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel or not (md.parent / rel).exists():
                bad.append((md.relative_to(root), target))
    return bad


def main(argv: list[str]) -> int:
    root = (Path(argv[1]) if len(argv) > 1
            else Path(__file__).resolve().parents[1])
    n_files = len(list(markdown_files(root)))
    bad = dead_links(root)
    for md, target in bad:
        print(f"{md}: dead relative link -> {target}")
    status = f"FAIL: {len(bad)} dead link(s)" if bad else "OK"
    print(f"[check_links] {status} across {n_files} markdown files")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
