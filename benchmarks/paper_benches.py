"""One benchmark per paper table/figure (DESIGN.md §6). All runtimes are
single-host CPU; what is measured is the *mechanism* the paper measured —
coordination overheads, sharded vs sampled softmax, backup-worker tails —
with sizes scaled to minutes, not the paper's absolute 2016 numbers."""

from __future__ import annotations

import re
import time

import numpy as np


def _parse_derived(derived: str) -> dict:
    """Parse the human-readable derived string into typed fields for the
    machine-readable (``--json``) output: ``tok_s=57.1`` becomes a float
    field, ``ttft_p95=3steps/41ms`` splits into ``ttft_p95_steps`` and
    ``ttft_p95_ms``."""
    fields: dict = {}
    for part in derived.split():
        key, _, val = part.partition("=")
        if not val:
            continue
        m = re.fullmatch(r"(-?[0-9.]+)steps/(-?[0-9.]+)ms", val)
        if m:
            fields[key + "_steps"] = float(m.group(1))
            fields[key + "_ms"] = float(m.group(2))
            continue
        try:
            fields[key] = float(val)
        except ValueError:
            fields[key] = val
    return fields


def _csv(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
    return {"name": name, "us_per_call": round(us, 2), "derived": derived,
            **_parse_derived(derived)}


# ---------------------------------------------------------------------------
# Table 1: single-machine step time / framework overhead
# ---------------------------------------------------------------------------


def bench_table1_step_time(rows):
    import jax
    import jax.numpy as jnp
    from repro.config import (OptimizerConfig, ParallelConfig, ShapeConfig,
                              get_config)
    from repro.models import api
    from repro.optim import optimizers as opt
    from repro.spmd import steps as steps_mod

    shape = ShapeConfig("bench", seq_len=32, global_batch=4, kind="train")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    pcfg = ParallelConfig(remat="full")
    ocfg = OptimizerConfig(warmup_steps=0, schedule="constant")
    for arch in ("glm4_9b", "starcoder2_3b", "gemma2_27b", "qwen3_32b",
                 "qwen3_moe_30b_a3b", "mamba2_370m"):
        cfg = get_config(arch, smoke=True)
        with jax.set_mesh(mesh):
            params_f32, _ = api.init_model(cfg, jax.random.key(0))
            opt_state = opt.init_train_state(ocfg, params_f32)
            params = jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                                  params_f32)
            step = jax.jit(steps_mod.make_train_step(cfg, pcfg, ocfg),
                           donate_argnums=(0, 1))
            batch = api.make_batch(cfg, shape)
            params, opt_state, m = step(params, opt_state,
                                        jnp.asarray(1), batch)   # compile
            jax.block_until_ready(m["loss"])
            n = 10
            t0 = time.perf_counter()
            for i in range(n):
                params, opt_state, m = step(params, opt_state,
                                            jnp.asarray(i), batch)
            jax.block_until_ready(m["loss"])
            dt = (time.perf_counter() - t0) / n
        tok_s = shape.global_batch * shape.seq_len / dt
        rows.append(_csv(f"table1/{arch}", dt * 1e6,
                         f"tok_s={tok_s:.0f}"))


# ---------------------------------------------------------------------------
# §2.1 production inference: the continuous-batching engine under ragged
# horizons (goodput per decode step; the mechanism behind the paper's
# "serving at scale" claim) — headline transformer row, prefix-cached row,
# speculative draft-and-verify rows, and the SSM / enc-dec runner rows
# ---------------------------------------------------------------------------


def _latency_percentiles(eng, reqs):
    """p50/p95 TTFT and end-to-end latency, in engine steps and wall
    seconds, from the engine's per-request latency records."""
    recs = [eng.stats["latency"][r.rid] for r in reqs]
    ttft_steps = [r["first_token_step"] - r["arrival_step"] for r in recs]
    ttft_wall = [r["first_token_wall"] - r["arrival_wall"] for r in recs]
    e2e_steps = [r["done_step"] - r["arrival_step"] for r in recs]
    e2e_wall = [r["done_wall"] - r["arrival_wall"] for r in recs]

    def pct(xs, q):
        return float(np.percentile(xs, q))

    return (f"ttft_p50={pct(ttft_steps, 50):.0f}steps/"
            f"{pct(ttft_wall, 50) * 1e3:.0f}ms "
            f"ttft_p95={pct(ttft_steps, 95):.0f}steps/"
            f"{pct(ttft_wall, 95) * 1e3:.0f}ms "
            f"e2e_p50={pct(e2e_steps, 50):.0f}steps/"
            f"{pct(e2e_wall, 50) * 1e3:.0f}ms "
            f"e2e_p95={pct(e2e_steps, 95):.0f}steps/"
            f"{pct(e2e_wall, 95) * 1e3:.0f}ms")


def bench_serving_throughput(rows):
    from repro.config import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.serving import InferenceEngine, Request

    cfg = get_config("glm4_9b", smoke=True)
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(0)
    n_req, prompt_len, max_batch = 12, 32, 4
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_req)]
    # ragged horizons: static batching would decode max() steps for all
    max_news = [4 + 4 * (i % 4) for i in range(n_req)]

    # prefix caching OFF for the headline row: the warmup run (for jit
    # compile) uses the same prompts, and cache hits would let the timed
    # run skip nearly all prefill — not representative of cold traffic
    eng = InferenceEngine(cfg, mesh, max_batch=max_batch, block_size=16,
                          max_len=128, enable_prefix_caching=False)
    reqs = [Request(p, max_new=mn) for p, mn in zip(prompts, max_news)]
    eng.run(reqs)                               # includes compile
    steps0 = eng.stats["steps"]
    t0 = time.perf_counter()
    eng2_reqs = [Request(p, max_new=mn) for p, mn in zip(prompts, max_news)]
    eng.run(eng2_reqs)
    dt_eng = time.perf_counter() - t0
    n_tok = sum(mn for mn in max_news)
    eng_steps = eng.stats["steps"] - steps0
    rows.append(_csv("serving/paged_engine", dt_eng / n_tok * 1e6,
                     f"tok_s={n_tok/dt_eng:.1f} "
                     f"slot_steps={eng_steps * max_batch} "
                     + _latency_percentiles(eng, eng2_reqs)))

    # the same workload through the async streaming front-end (driver +
    # admission control + per-request token streams; docs/
    # serving-frontend.md) on the warm headline engine: measures the
    # front-end's overhead over the bare batch driver — the admission
    # path live HTTP traffic takes, so this row and the headline stay
    # comparable by construction (no SLO target: nothing sheds)
    import asyncio

    from repro.serving.frontend import AdmissionController, AsyncEngineDriver

    fe_reqs = [Request(p, max_new=mn) for p, mn in zip(prompts, max_news)]
    fe_adm = AdmissionController()

    async def _stream_workload():
        async with AsyncEngineDriver(eng, admission=fe_adm) as drv:
            streams = [await drv.submit(r) for r in fe_reqs]

            async def pull(s):
                return [ev.token async for ev in s]

            await asyncio.gather(*(pull(s) for s in streams))

    t0 = time.perf_counter()
    asyncio.run(_stream_workload())
    dt_fe = time.perf_counter() - t0
    rows.append(_csv("serving/frontend_stream", dt_fe / n_tok * 1e6,
                     f"tok_s={n_tok/dt_fe:.1f} "
                     f"submitted={fe_adm.submitted} shed={fe_adm.shed} "
                     f"queue_peak={fe_adm.queue_peak} "
                     + _latency_percentiles(eng, fe_reqs)))

    # the prefix-cache benefit, measured explicitly: same prompts through
    # a caching engine whose cache the warmup run populated
    engc = InferenceEngine(cfg, mesh, max_batch=max_batch, block_size=16,
                           max_len=128, params=eng.params)
    engc.run([Request(p, max_new=mn) for p, mn in zip(prompts, max_news)])
    t0 = time.perf_counter()
    engc_reqs = [Request(p, max_new=mn) for p, mn in zip(prompts, max_news)]
    engc.run(engc_reqs)
    dt_c = time.perf_counter() - t0
    rows.append(_csv("serving/paged_engine_prefix_cached",
                     dt_c / n_tok * 1e6,
                     f"tok_s={n_tok/dt_c:.1f} "
                     f"cache_hit_tokens={engc.stats['cache_hit_tokens']} "
                     + _latency_percentiles(engc, engc_reqs)))

    # speculative decoding (draft-and-verify): a repetitive-prompt
    # workload decoded with and without a k=2 self-draft (draft shares the
    # target's params, so the draft agrees with the target wherever the
    # decode/verify numerics do — mean accept length ~ k+1 and the row
    # isolates the mechanism's accounting + verify-step overhead rather
    # than draft quality). Prefix caching off, like the headline row.
    scfg = get_config("starcoder2_3b", smoke=True)
    pattern = np.tile(np.arange(7, dtype=np.int32), 1 + prompt_len // 7)
    sprompts = [np.roll(pattern, i)[:prompt_len].astype(np.int32)
                for i in range(n_req)]

    def spec_reqs():
        return [Request(p, max_new=mn)
                for p, mn in zip(sprompts, max_news)]

    soff = InferenceEngine(scfg, mesh, max_batch=max_batch, block_size=16,
                           max_len=128, enable_prefix_caching=False)
    soff.run(spec_reqs())                       # compile
    t0 = time.perf_counter()
    soff.run(spec_reqs())
    dt_off = time.perf_counter() - t0
    rows.append(_csv("serving/speculative_off", dt_off / n_tok * 1e6,
                     f"tok_s={n_tok/dt_off:.1f} mean_accept_len=1.0"))
    son = InferenceEngine(scfg, mesh, max_batch=max_batch, block_size=16,
                          max_len=128, enable_prefix_caching=False,
                          params=soff.params, draft_params=soff.params,
                          num_speculative_tokens=2)
    son.run(spec_reqs())                        # compile
    t0 = time.perf_counter()
    son.run(spec_reqs())
    dt_on = time.perf_counter() - t0
    rows.append(_csv("serving/speculative_k2", dt_on / n_tok * 1e6,
                     f"tok_s={n_tok/dt_on:.1f} "
                     f"mean_accept_len={son.stats['mean_accept_len']:.3f} "
                     f"steps={son.stats['steps']}"))

    # the non-transformer runners on the same hot path: pure SSM (slot
    # state, no block pool) and enc-dec (paged self-KV + admission-time
    # encoder passes) — the workload families the runner refactor opened
    for arch, plen in (("mamba2_370m", 24), ("whisper_large_v3", 8)):
        fcfg = get_config(arch, smoke=True)
        fprompts = [rng.integers(0, fcfg.vocab_size, plen).astype(np.int32)
                    for _ in range(n_req)]
        fframes = [rng.normal(0, 1, (fcfg.encoder_seq_len, fcfg.d_model)
                              ).astype(np.float32)
                   if fcfg.frontend == "audio" else None
                   for _ in range(n_req)]
        feng = InferenceEngine(fcfg, mesh, max_batch=max_batch,
                               block_size=16, max_len=128)

        def make_reqs():
            return [Request(p, max_new=mn, frames=f)
                    for p, mn, f in zip(fprompts, max_news, fframes)]

        feng.run(make_reqs())                   # compile
        t0 = time.perf_counter()
        freqs = make_reqs()
        feng.run(freqs)
        dt_f = time.perf_counter() - t0
        rows.append(_csv(f"serving/paged_engine_{arch}",
                         dt_f / n_tok * 1e6,
                         f"tok_s={n_tok/dt_f:.1f} "
                         f"encodes={feng.stats['encodes']} "
                         + _latency_percentiles(feng, freqs)))

    # tensor-parallel row: the headline workload on a forced 2-device host
    # mesh (page pools sharded by kv head over "model"; docs/multi-host.md).
    # Runs in a subprocess because the virtual device count is fixed at
    # process start. On CPU this measures the TP *overhead* (collectives +
    # per-shard dispatch on virtual devices), not a speedup — the row
    # exists so the sharded step's hot path is timed and smoke-checked.
    import os
    import subprocess
    import sys
    tp_code = (
        "import jax, jax.numpy as jnp, numpy as np, time\n"
        "import repro.compat\n"
        "from repro.config import get_config\n"
        "from repro.serving import InferenceEngine, Request\n"
        "cfg = get_config('glm4_9b', smoke=True)\n"
        "mesh = jax.make_mesh((1, 2), ('data', 'model'),\n"
        "    axis_types=(jax.sharding.AxisType.Auto,) * 2)\n"
        "rng = np.random.default_rng(0)\n"
        "prompts = [rng.integers(0, cfg.vocab_size, 32).astype(np.int32)\n"
        "           for _ in range(12)]\n"
        "max_news = [4 + 4 * (i % 4) for i in range(12)]\n"
        "eng = InferenceEngine(cfg, mesh, max_batch=4, block_size=16,\n"
        "                      max_len=128, enable_prefix_caching=False)\n"
        "reqs = lambda: [Request(p, max_new=mn)\n"
        "                for p, mn in zip(prompts, max_news)]\n"
        "eng.run(reqs())\n"
        "t0 = time.perf_counter()\n"
        "eng.run(reqs())\n"
        "print('TP2RESULT', time.perf_counter() - t0, sum(max_news))\n"
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                        + env.get("XLA_FLAGS", "")).strip()
    proc = subprocess.run([sys.executable, "-c", tp_code],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("TP2RESULT"))
    dt_tp, n_tp = float(line.split()[1]), int(line.split()[2])
    rows.append(_csv("serving/paged_engine_tp2", dt_tp / n_tp * 1e6,
                     f"tok_s={n_tp/dt_tp:.1f} mesh=model2"))


# ---------------------------------------------------------------------------
# Ragged packed prefill: a bursty multi-prompt workload served with
# prefill_pack=1 (classic single-chunk admission) vs prefill_pack=4 (several
# prompts' chunks packed into one flat ragged token batch per step). The
# packed row must beat the baseline on admitted tokens/s and TTFT p95 —
# that delta is the tentpole claim of the ragged-prefill kernel work.
# ---------------------------------------------------------------------------


def bench_serving_ragged_prefill(rows):
    from repro.config import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.serving import InferenceEngine, Request

    cfg = get_config("glm4_9b", smoke=True)
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(7)
    n_req, prompt_len, max_batch = 16, 24, 8
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_req)]
    n_tok = n_req * 4

    shared_params = None
    for pack, row_name in ((1, "serving/ragged_prefill_base"),
                           (4, "serving/ragged_prefill")):
        # budget 104 leaves chunk_width 96 after the 8-wide decode batch:
        # exactly four 24-token prompts per packed step vs one for the
        # baseline — the burst drains 4x faster through prefill
        eng = InferenceEngine(cfg, mesh, max_batch=max_batch, block_size=16,
                              max_len=128, max_num_batched_tokens=104,
                              enable_prefix_caching=False,
                              prefill_pack=pack, params=shared_params)
        shared_params = eng.params          # identical weights both rows

        def mk():
            return [Request(p, max_new=4) for p in prompts]

        eng.run(mk())                       # compile
        t0 = time.perf_counter()
        reqs = mk()
        eng.run(reqs, arrival_steps=[0] * n_req)     # one burst
        dt = time.perf_counter() - t0
        rows.append(_csv(row_name, dt / n_tok * 1e6,
                         f"tok_s={n_tok/dt:.1f} prefill_pack={pack} "
                         f"steps={eng.stats['steps']} "
                         + _latency_percentiles(eng, reqs)))


# ---------------------------------------------------------------------------
# KV tiering: quantized int8 pages at a matched device-pool byte budget
# (the int8 pool holds ~2x the blocks, so the same bytes serve deeper
# contexts), and swap-vs-recompute preemption under the scheduler cost
# model (policy "always" vs "never" on the same small pool; outputs must
# be byte-identical either way — swapped KV is an exact copy and
# recompute follows the repo rounding convention).
# ---------------------------------------------------------------------------


def bench_serving_kv_tiering(rows):
    from repro.config import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.serving import InferenceEngine, Request
    from repro.serving.kv_cache import block_bytes

    cfg = get_config("glm4_9b", smoke=True)
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(11)
    n_req, prompt_len, max_batch = 12, 32, 4
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_req)]
    max_news = [4 + 4 * (i % 4) for i in range(n_req)]
    n_tok = sum(max_news)

    def mk():
        return [Request(p, max_new=mn) for p, mn in zip(prompts, max_news)]

    # -- matched pool bytes: bf16 vs int8 ---------------------------------
    # Both engines get the same device-pool byte budget (40 bf16 blocks'
    # worth). The int8 pool's K/V payload is exactly half the bytes per
    # row (2*head_dim -> head_dim), so payload capacity is 2.0x; the fp32
    # per-row scale sidecar carried alongside costs 4/(head_dim+4) of the
    # quantized block, which is what separates the realized block-count
    # ratio from the payload ratio.
    bb = {d: block_bytes(cfg, 16, kv_dtype=d) for d in ("bf16", "int8")}
    pool_bytes = 40 * bb["bf16"]
    hd = cfg.head_dim
    shared_params = None
    n_blocks = {}
    for dtype, row_name in (("bf16", "serving/kv_bf16_base"),
                            ("int8", "serving/kv_int8")):
        n_blocks[dtype] = pool_bytes // bb[dtype]
        eng = InferenceEngine(cfg, mesh, max_batch=max_batch, block_size=16,
                              max_len=128, num_blocks=n_blocks[dtype],
                              kv_dtype=dtype, params=shared_params)
        shared_params = eng.params          # identical weights both rows
        eng.run(mk())                       # compile
        t0 = time.perf_counter()
        eng.run(mk())
        dt = time.perf_counter() - t0
        derived = (f"tok_s={n_tok/dt:.1f} num_blocks={n_blocks[dtype]} "
                   f"kv_cache_mib={eng.stats['kv_cache_mib']:.3f}")
        if dtype == "int8":
            derived += (
                f" capacity_ratio={n_blocks['int8']/n_blocks['bf16']:.2f}"
                f" payload_ratio={2*hd/hd:.1f}"
                f" scale_overhead={4/(hd+4):.3f}")
        rows.append(_csv(row_name, dt / n_tok * 1e6, derived))

    # -- swap vs recompute preemption -------------------------------------
    # A pool too small for the full working set forces preemptions; the
    # "never" row resolves every victim by releasing blocks and
    # recomputing the prefix, the "always" row by swapping pages to the
    # pinned host tier and copying them back on re-admission. Greedy
    # outputs are asserted byte-identical across the two policies.
    swap_max_news = [8 + 8 * (i % 3) for i in range(n_req)]
    n_swap_tok = sum(swap_max_news)

    def mk_swap():
        return [Request(p, max_new=mn)
                for p, mn in zip(prompts, swap_max_news)]

    swap_bytes = 32 * bb["bf16"]
    outs = {}
    for policy, row_name in (("never", "serving/swap_recompute_base"),
                             ("always", "serving/swap_vs_recompute")):
        eng = InferenceEngine(cfg, mesh, max_batch=max_batch, block_size=16,
                              max_len=128, num_blocks=10,
                              swap_space_bytes=swap_bytes,
                              swap_policy=policy, params=shared_params)
        eng.run(mk_swap())                  # compile
        t0 = time.perf_counter()
        reqs = mk_swap()
        out = eng.run(reqs)
        dt = time.perf_counter() - t0
        outs[policy] = [out[r.rid] for r in reqs]
        rows.append(_csv(
            row_name, dt / n_swap_tok * 1e6,
            f"tok_s={n_swap_tok/dt:.1f} policy={policy} "
            f"preemptions={eng.stats['preemptions']} "
            f"swap_preemptions={eng.stats['swap_preemptions']} "
            f"swap_ins={eng.stats['swap_ins']} "
            f"swapped_out_blocks={eng.stats['swapped_out_blocks']} "
            f"swapped_in_blocks={eng.stats['swapped_in_blocks']} "
            + _latency_percentiles(eng, reqs)))
    for a, b in zip(outs["never"], outs["always"]):
        assert np.array_equal(a, b), "swap vs recompute outputs diverged"


# ---------------------------------------------------------------------------
# Production sampling surface (docs/sampling.md): the full in-jit pipeline
# (top-p + min-p + penalties + logprobs, per slot) vs the pure-greedy fast
# path on the identical workload — the cost of the richer per-slot
# transform, isolated from model/runner differences.
# ---------------------------------------------------------------------------


def bench_serving_sampling(rows):
    from repro.config import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.serving import InferenceEngine, Request
    from repro.serving.scheduler import SamplingParams

    cfg = get_config("glm4_9b", smoke=True)
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(21)
    n_req, prompt_len, max_batch = 12, 32, 4
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_req)]
    max_news = [4 + 4 * (i % 4) for i in range(n_req)]
    n_tok = sum(max_news)
    full_sp = [SamplingParams(temperature=0.9, top_k=16, top_p=0.85,
                              min_p=0.02, repetition_penalty=1.2,
                              frequency_penalty=0.1, logprobs=4, seed=i)
               for i in range(n_req)]

    def mk(sps=None):
        return [Request(p, max_new=mn,
                        sampling=sps[i] if sps else SamplingParams())
                for i, (p, mn) in enumerate(zip(prompts, max_news))]

    shared_params = None
    dts = {}
    for name, sps in (("serving/sampling_greedy_base", None),
                      ("serving/sampling_full", full_sp)):
        eng = InferenceEngine(cfg, mesh, max_batch=max_batch, block_size=16,
                              max_len=128, enable_prefix_caching=False,
                              params=shared_params)
        shared_params = eng.params          # identical weights both rows
        eng.run(mk(sps))                    # compile
        t0 = time.perf_counter()
        eng.run(mk(sps))
        dts[name] = dt = time.perf_counter() - t0
        derived = (f"tok_s={n_tok/dt:.1f} "
                   f"full_sampling_steps={eng.stats['full_sampling_steps']}")
        if sps is None:
            assert eng.stats["full_sampling_steps"] == 0  # fast path held
        else:
            derived += (" overhead_ratio="
                        f"{dt/dts['serving/sampling_greedy_base']:.3f}")
        rows.append(_csv(name, dt / n_tok * 1e6, derived))


# ---------------------------------------------------------------------------
# Data-parallel replicas behind the ReplicaRouter (docs/multi-host.md): a
# burst workload drained by dp=1 vs dp=2 fleets (same per-replica config,
# shared prefix index), plus the disaggregated prefill/decode split. Wall
# tok_s is reported as measured; on a single-core host the replicas'
# threads serialize, so dp scaling is additionally reported on the fleet
# *step* clock — max over replicas' engine steps, which is what wall time
# tracks when each replica owns real hardware (same deterministic virtual
# clock the ttft_steps percentiles use).
# ---------------------------------------------------------------------------


def bench_serving_dp(rows):
    from repro.config import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.serving import (InferenceEngine, ReplicaRouter, Request,
                               SharedPrefixIndex)

    cfg = get_config("glm4_9b", smoke=True)
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(0)
    n_req, prompt_len, max_batch = 16, 32, 4
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_req)]
    warm = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
            for _ in range(n_req)]
    # uniform horizons: a burst of equal-cost requests, so the router's
    # least-outstanding-tokens placement splits the fleet evenly and the
    # scaling number measures replication, not workload skew (raggedness
    # is the serving_throughput rows' subject)
    max_new = 12
    n_tok = n_req * max_new

    def mk(ps, base):
        return [Request(p.copy(), max_new=max_new, rid=base + i)
                for i, p in enumerate(ps)]

    shared_params = None
    results = {}
    for dp, name in ((1, "serving/dp1"), (2, "serving/dp2")):
        shared = SharedPrefixIndex(num_slots=256)
        engines = [InferenceEngine(cfg, mesh, max_batch=max_batch,
                                   block_size=16, max_len=128,
                                   params=shared_params,
                                   shared_index=shared)
                   for _ in range(dp)]
        shared_params = engines[0].params   # identical weights, all rows
        router = ReplicaRouter(engines)
        router.run(mk(warm, 90000))         # compile + warm the replicas
        steps0 = [e.stats["steps"] for e in engines]
        routed0 = list(router.routed)
        t0 = time.perf_counter()
        router.run(mk(prompts, 91000))      # the burst: all arrive at once
        dt = time.perf_counter() - t0
        steps = [e.stats["steps"] - s0 for e, s0 in zip(engines, steps0)]
        fleet_steps = max(steps)            # replicas step concurrently
        results[name] = (dt, fleet_steps)
        routed = [n - n0 for n, n0 in zip(router.routed, routed0)]
        derived = (f"tok_s={n_tok/dt:.1f} fleet_steps={fleet_steps} "
                   f"routed={'/'.join(str(n) for n in routed)} "
                   f"shared_published_blocks="
                   f"{shared.stats()['published_blocks']}")
        if dp > 1:
            dt1, fs1 = results["serving/dp1"]
            derived += (f" wall_speedup_vs_dp1={dt1/dt:.2f} "
                        f"step_speedup_vs_dp1={fs1/fleet_steps:.2f}")
        rows.append(_csv(name, dt / n_tok * 1e6, derived))

    # disaggregated prefill/decode: probe on the prefill replica, decode
    # continuation adopts the published blocks through the shared index
    shared = SharedPrefixIndex(num_slots=256)
    engines = [InferenceEngine(cfg, mesh, max_batch=max_batch,
                               block_size=16, max_len=128,
                               params=shared_params, shared_index=shared)
               for _ in range(2)]
    router = ReplicaRouter(engines, disaggregate=True)
    router.run(mk(warm, 92000))
    steps0 = [e.stats["steps"] for e in engines]
    handoffs0 = router.handoffs
    t0 = time.perf_counter()
    router.run(mk(prompts, 93000))
    dt = time.perf_counter() - t0
    steps = [e.stats["steps"] - s0 for e, s0 in zip(engines, steps0)]
    rows.append(_csv(
        "serving/disagg_prefill_decode", dt / n_tok * 1e6,
        f"tok_s={n_tok/dt:.1f} fleet_steps={max(steps)} "
        f"handoffs={router.handoffs - handoffs0} "
        f"decode_shared_hit_blocks={engines[1].stats['shared_hit_blocks']} "
        f"prefill_published_blocks="
        f"{engines[0].stats['shared_published_blocks']}"))


# ---------------------------------------------------------------------------
# Paged-attention kernel rows: decode and chunked prefill through the
# dispatch layer with the pages_per_compute_block knob, plus the ragged
# packed-prefill op (fused KV scatter + attention). On CPU these time the
# XLA dispatch path (the knob is a no-op there); on TPU the same calls hit
# the Pallas kernels with multi-page fetch and megacore grid partitioning,
# so the rows track the kernel campaign wherever the bench runs.
# ---------------------------------------------------------------------------


def bench_paged_kernels(rows):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    rng = np.random.default_rng(0)
    B, H, K, hd, bs, nb = 8, 8, 4, 64, 16, 8
    num_blocks = B * nb + 1
    k_pages = jnp.asarray(rng.normal(0, 1, (num_blocks, bs, K, hd)),
                          jnp.bfloat16)
    v_pages = jnp.asarray(rng.normal(0, 1, (num_blocks, bs, K, hd)),
                          jnp.bfloat16)
    tables = jnp.asarray(
        1 + np.arange(B * nb, dtype=np.int32).reshape(B, nb))
    ctx = jnp.asarray(rng.integers(bs, nb * bs + 1, B), jnp.int32)

    def timeit(fn, *args):
        jfn = jax.jit(fn)
        out = jax.block_until_ready(jfn(*args))
        n = 20
        t0 = time.perf_counter()
        for _ in range(n):
            out = jfn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n

    q_d = jnp.asarray(rng.normal(0, 1, (B, H, hd)), jnp.bfloat16)
    for p, name in ((1, "kernels/paged_decode"),
                    (4, "kernels/paged_decode_mp")):
        dt = timeit(lambda q, pp=p: kops.paged_attention(
            q, k_pages, v_pages, tables, ctx,
            pages_per_compute_block=pp), q_d)
        rows.append(_csv(name, dt * 1e6,
                         f"tok_s={B/dt:.0f} pages_per_block={p} "
                         f"backend={backend}"))

    C = 32
    q_p = jnp.asarray(rng.normal(0, 1, (B, C, H, hd)), jnp.bfloat16)
    q_lens = jnp.minimum(ctx, C)
    dt = timeit(lambda q: kops.paged_prefill_attention(
        q, k_pages, v_pages, tables, ctx, q_lens,
        pages_per_compute_block=4), q_p)
    rows.append(_csv("kernels/paged_prefill_mp", dt * 1e6,
                     f"tok_s={int(q_lens.sum())/dt:.0f} pages_per_block=4 "
                     f"backend={backend}"))

    # ragged packed prefill: S=4 sequences' chunks in one flat T=64 batch,
    # chunk KV scattered and attended in one op (fused on the Pallas path)
    S, T = 4, 64
    lens = np.full(S, T // S, np.int32)
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]]).astype(np.int32)
    ends = (starts + lens).astype(np.int32)
    row_seq = np.repeat(np.arange(S, dtype=np.int32), lens)
    r_ctx = jnp.asarray(bs + lens, jnp.int32)     # one context block + chunk
    r_tables = tables[:S]
    q_r = jnp.asarray(rng.normal(0, 1, (T, H, hd)), jnp.bfloat16)
    k_new = jnp.asarray(rng.normal(0, 1, (T, K, hd)), jnp.bfloat16)
    v_new = jnp.asarray(rng.normal(0, 1, (T, K, hd)), jnp.bfloat16)
    dt = timeit(lambda q: kops.ragged_prefill_update_attend(
        q, k_new, v_new, k_pages, v_pages, r_tables, r_ctx,
        jnp.asarray(starts), jnp.asarray(ends), jnp.asarray(row_seq)), q_r)
    rows.append(_csv("kernels/ragged_prefill", dt * 1e6,
                     f"tok_s={T/dt:.0f} packed_seqs={S} "
                     f"backend={backend}"))


# ---------------------------------------------------------------------------
# Figure 6: null-step synchronous replication (scalar / dense / sparse)
# ---------------------------------------------------------------------------


def bench_fig6_null_step(rows):
    import numpy as np
    from repro.core.cluster import Cluster
    from repro.core.graph import Graph
    from repro.core.gradients import gradients
    from repro.core.session import Session
    import threading

    n_ps = 4
    dense_mb = 8        # "dense" model size in MB (paper: 100MB/1GB)
    emb_rows = 65536    # "sparse" table rows (step cost must not scale)

    for variant in ("scalar", "dense", "sparse"):
        for n_workers in (1, 2, 4, 8):
            g = Graph()
            cl = Cluster(ps=n_ps, worker=n_workers)
            sess = Session(g, cl, default_device="worker:0")
            reads, updates = [], []
            if variant == "scalar":
                shapes = [(1,)] * n_ps
            elif variant == "dense":
                per = dense_mb * 1024 * 1024 // 4 // n_ps
                shapes = [(per,)] * n_ps
            else:
                shapes = [(emb_rows // n_ps, 16)] * n_ps
            for i, shp in enumerate(shapes):
                h = g.apply("Variable", var_name=f"w{i}",
                            initial=np.zeros(shp, np.float32),
                            device=f"ps:{i}")
                if variant == "sparse":
                    ids = g.constant(np.arange(32) % shp[0])
                    rd = g.apply("Gather", g.apply("Read", h), ids)
                    rd.op.colocation = h.op.name
                    upd = g.apply("ScatterAdd", h, ids,
                                  g.constant(np.ones((32, 16), np.float32)
                                             * 1e-6))
                else:
                    rd = g.apply("Read", h)
                    upd = g.apply("AssignAdd", h, g.constant(
                        np.float32(1e-6)))
                reads.append(rd)
                updates.append(upd)
            # per-worker fetch+update closure over worker device
            fetch = [g.apply("ReduceSum", r) for r in reads]
            times = []

            def worker_loop(w, n=6):
                for _ in range(n):
                    t0 = time.perf_counter()
                    sess.run(fetch + updates)
                    times.append(time.perf_counter() - t0)

            threads = [threading.Thread(target=worker_loop, args=(w,),
                                        daemon=True)
                       for w in range(n_workers)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            med = float(np.median(times)) if times else 0.0
            rows.append(_csv(f"fig6/{variant}/workers{n_workers}",
                             med * 1e6, f"median_step_ms={med*1e3:.2f}"))


# ---------------------------------------------------------------------------
# Figure 7: throughput scaling, async vs sync
# ---------------------------------------------------------------------------


def bench_fig7_scaling(rows):
    from repro.core.cluster import Cluster
    from repro.core.graph import Graph
    from repro.ps.training import PSTrainer, linear_model

    rng = np.random.default_rng(0)
    W = rng.normal(0, 1, (64, 32)).astype(np.float32)

    def batch_fn(w, s):
        x = rng.normal(0, 1, (64, 64)).astype(np.float32)
        return x, (x @ W).argmax(-1)

    steps = 10
    for mode in ("async", "sync"):
        for n_workers in (1, 2, 4, 8):
            g = Graph()
            cl = Cluster(ps=2, worker=n_workers)
            tr = PSTrainer(linear_model(g, 64, 32, 2), cl, mode=mode,
                           n_workers=n_workers, lr=0.1)
            t0 = time.perf_counter()
            stats = tr.train(steps, batch_fn)
            wall = time.perf_counter() - t0
            total_steps = steps * (n_workers if mode == "async" else 1)
            thr = total_steps * 64 / wall     # examples/sec
            rows.append(_csv(f"fig7/{mode}/workers{n_workers}",
                             wall / total_steps * 1e6,
                             f"examples_s={thr:.0f}"))


# ---------------------------------------------------------------------------
# Figure 8: backup workers under injected stragglers
# ---------------------------------------------------------------------------


def bench_fig8_backup_workers(rows):
    from repro.core.cluster import Cluster
    from repro.core.graph import Graph
    from repro.ps.training import PSTrainer, linear_model

    rng = np.random.default_rng(0)
    W = rng.normal(0, 1, (32, 16)).astype(np.float32)

    def batch_fn(w, s):
        x = rng.normal(0, 1, (32, 32)).astype(np.float32)
        return x, (x @ W).argmax(-1)

    n = 6
    t0_med = None
    for b in (0, 1, 2, 3):
        g = Graph()
        cl = Cluster(ps=2, worker=n)
        tr = PSTrainer(linear_model(g, 32, 16, 2), cl,
                       mode="backup" if b else "sync", n_workers=n,
                       backup_workers=b, lr=0.1,
                       straggler_s=0.03, straggler_every=3)
        stats = tr.train(8, batch_fn)
        med = float(np.median(stats.step_times))
        if b == 0:
            t0_med = med
        # paper's normalized speedup: t(b)/t(0) * n/(n+b) — they normalize
        # by total resources; our workers are fixed so use t(0)/t(b) * n/(n)
        norm = (t0_med / med) * (n - b) / n
        rows.append(_csv(f"fig8/backup{b}", med * 1e6,
                         f"normalized_speedup={norm:.3f} "
                         f"discarded={stats.discarded}"))


# ---------------------------------------------------------------------------
# Figure 9: LM throughput, full vs sampled softmax x PS tasks
# ---------------------------------------------------------------------------


def bench_fig9_softmax(rows):
    from repro.core.cluster import Cluster
    from repro.core.graph import Graph
    from repro.ps.lm import lm_batch_fn, lstm_lm_model
    from repro.ps.training import PSTrainer

    vocab, d, unroll, batch = 8192, 64, 8, 64
    for softmax in ("full", "sampled"):
        for n_ps in (1, 2, 4):
            g = Graph()
            cl = Cluster(ps=n_ps, worker=2)
            model = lstm_lm_model(g, vocab=vocab, d=d, unroll=unroll,
                                  n_ps=n_ps, softmax=softmax)
            tr = PSTrainer(model, cl, mode="async", n_workers=2, lr=0.05)
            steps = 6
            t0 = time.perf_counter()
            tr.train(steps, lm_batch_fn(vocab, batch, unroll))
            wall = time.perf_counter() - t0
            words_s = steps * 2 * batch / wall
            rows.append(_csv(f"fig9/{softmax}/ps{n_ps}",
                             wall / (steps * 2) * 1e6,
                             f"words_s={words_s:.0f}"))


# ---------------------------------------------------------------------------
# §5 executor dispatch rate ("2,000,000 null operations per second")
# ---------------------------------------------------------------------------


def bench_executor_dispatch(rows):
    from repro.core.cluster import Cluster
    from repro.core.graph import Graph
    from repro.core.session import Session

    g = Graph()
    cl = Cluster(worker=1)
    sess = Session(g, cl)
    x = g.constant(np.float32(1.0))
    n_ops = 2000
    for _ in range(n_ops):
        x = g.apply("Identity", x)
    sess.run(x)                      # build + cache plan
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        sess.run(x)
    dt = time.perf_counter() - t0
    ops_s = n_ops * reps / dt
    rows.append(_csv("executor/null_op_dispatch", dt / reps / n_ops * 1e6,
                     f"ops_per_s={ops_s:.0f}"))
