"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (DESIGN.md §6 maps each to the
paper's Table 1 / Figures 6-9 / §5 executor claim).

``--json OUT.json`` additionally writes the rows machine-readable: every
row carries ``name``, ``us_per_call`` and the derived string parsed into
typed fields (``tok_s``, ``ttft_p50_steps``, ``ttft_p95_ms``, ...), so CI
can archive the bench trajectory and tools can diff runs without scraping
the CSV. A positional filter selects benches by substring, comma-
separated: ``python benchmarks/run.py serving,paged_kernels``.
"""

from __future__ import annotations

import json
import sys
import time


def main() -> None:
    from benchmarks import paper_benches as pb

    args = sys.argv[1:]
    json_out = None
    if "--json" in args:
        i = args.index("--json")
        json_out = args[i + 1]
        del args[i:i + 2]
    only = args[0].split(",") if args else None

    rows: list[dict] = []
    print("name,us_per_call,derived")
    benches = [
        pb.bench_table1_step_time,
        pb.bench_serving_throughput,
        pb.bench_serving_ragged_prefill,
        pb.bench_serving_kv_tiering,
        pb.bench_serving_sampling,
        pb.bench_serving_dp,
        pb.bench_paged_kernels,
        pb.bench_fig6_null_step,
        pb.bench_fig7_scaling,
        pb.bench_fig8_backup_workers,
        pb.bench_fig9_softmax,
        pb.bench_executor_dispatch,
    ]
    t0 = time.time()
    for bench in benches:
        if only and not any(o in bench.__name__ for o in only):
            continue
        try:
            bench(rows)
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"{bench.__name__},ERROR,{type(e).__name__}: {e}")
    print(f"# {len(rows)} rows in {time.time()-t0:.1f}s")
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"rows": rows}, f, indent=1)
        print(f"# wrote {json_out}")


if __name__ == "__main__":
    main()
