"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (DESIGN.md §6 maps each to the
paper's Table 1 / Figures 6-9 / §5 executor claim)."""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import paper_benches as pb

    rows: list[dict] = []
    print("name,us_per_call,derived")
    benches = [
        pb.bench_table1_step_time,
        pb.bench_serving_throughput,
        pb.bench_fig6_null_step,
        pb.bench_fig7_scaling,
        pb.bench_fig8_backup_workers,
        pb.bench_fig9_softmax,
        pb.bench_executor_dispatch,
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    t0 = time.time()
    for bench in benches:
        if only and only not in bench.__name__:
            continue
        try:
            bench(rows)
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"{bench.__name__},ERROR,{type(e).__name__}: {e}")
    print(f"# {len(rows)} rows in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
